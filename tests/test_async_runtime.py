"""Async pipelined runtime: equivalence, ordering, and incrementality.

The pipelined runtime (repro.train.runtime) only moves *when* host work
happens — planning is one-step-delayed by design — so async and sync
must produce bit-identical loss histories and per-step placements.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import (GatingTrace, GreedyPlanner, HardwareSpec, PerfModel,
                        ProProphetEngine, traditional)
from repro.core.engine import EngineConfig
from repro.data import SyntheticLM
from repro.launch.mesh import mesh_axis_names
from repro.optim import adamw, cosine
from repro.parallel import local_ctx
from repro.train import Trainer
from repro.train.runtime import (OverlapTelemetry, PlacementCache,
                                 PlanPipeline, StepStats, fingerprint_arrays)
from repro.train.trainer import make_engine_for


def _hw():
    return HardwareSpec.from_model_dims(512, 1024, bandwidth=25e9,
                                        flops_per_s=70e12)


def _engine(layers=2, d=4, e=8, replan_interval=1, policy="pro_prophet"):
    cfg = EngineConfig(num_experts=e, num_devices=d, num_moe_layers=layers,
                       s_max=4, replan_interval=replan_interval,
                       policy=policy)
    return ProProphetEngine(cfg, _hw())


# ---------------------------------------------------------------------------
# Tentpole acceptance: async ≡ sync, bit-identical
# ---------------------------------------------------------------------------

class TestAsyncSyncEquivalence:
    @pytest.mark.parametrize("replan_interval", [1, 3])
    def test_bit_identical_losses_and_placements(self, replan_interval):
        """Same seeds/batches ⇒ identical loss histories AND identical
        per-step placement arrays under both runtimes (≥20 steps)."""
        cfg = reduced(get_config("moe-gpt-s"))
        ctx = local_ctx()
        steps = 22
        tr = Trainer(cfg, ctx, adamw(cosine(3e-3, 5, steps)),
                     attn_impl="naive", remat=False,
                     engine=make_engine_for(
                         cfg, ctx, replan_interval=replan_interval))

        def run(async_mode):
            # fresh engine, same compiled step — modes must not share
            # planner state
            tr.engine = make_engine_for(cfg, ctx,
                                        replan_interval=replan_interval)
            tr.async_plan = async_mode
            state = tr.init_state(jax.random.PRNGKey(0))
            data = SyntheticLM(cfg, batch=4, seq=32)
            sink, tel = [], OverlapTelemetry()
            state, hist = tr.run(state, data, num_steps=steps, log_every=0,
                                 stats_sink=sink, telemetry=tel)
            return hist, sink, tel

        hist_s, sink_s, _ = run(False)
        hist_a, sink_a, tel_a = run(True)
        assert hist_s == hist_a                      # bit-identical floats
        assert len(sink_s) == len(sink_a) == steps
        for st_s, st_a in zip(sink_s, sink_a):
            assert st_s.step == st_a.step
            assert st_s.placements_fingerprint == st_a.placements_fingerprint
            assert st_s.placements_version == st_a.placements_version
        # telemetry surface is populated
        s = tel_a.summary()
        assert s["steps"] == steps
        assert s["mean_plan_s"] > 0.0

    def test_sync_mode_fully_exposed_async_hides(self):
        """Sync stats report hidden_frac == 0; the async runtime reports
        the exposed residual ≤ plan time."""
        cfg = reduced(get_config("moe-gpt-s"))
        ctx = local_ctx()
        tr = Trainer(cfg, ctx, adamw(1e-3), attn_impl="naive", remat=False,
                     engine=make_engine_for(cfg, ctx), async_plan=False)
        state = tr.init_state(jax.random.PRNGKey(0))
        data = SyntheticLM(cfg, batch=4, seq=32)
        sink = []
        tr.run(state, data, num_steps=3, log_every=0, stats_sink=sink)
        for st in sink:
            if st.plan_time > 0:
                assert st.hidden_frac == 0.0
                assert st.exposed_plan_time == pytest.approx(st.plan_time)

    def test_engine_state_drained_after_async_run(self):
        """The final step's observe lands before run() returns."""
        cfg = reduced(get_config("moe-gpt-s"))
        ctx = local_ctx()
        eng = make_engine_for(cfg, ctx)
        tr = Trainer(cfg, ctx, adamw(1e-3), attn_impl="naive", remat=False,
                     engine=eng, async_plan=True)
        state = tr.init_state(jax.random.PRNGKey(0))
        data = SyntheticLM(cfg, batch=4, seq=32)
        tr.run(state, data, num_steps=3, log_every=0)
        assert eng.planners[0].tracker.latest is not None
        assert eng.planners[0].tracker.latest.sum() == \
            4 * 32 * cfg.moe.top_k


# ---------------------------------------------------------------------------
# No torn placement reads: planner results land before dependent dispatch
# ---------------------------------------------------------------------------

class _SlowEngine:
    """Engine stub whose observe sleeps, to widen any ordering race."""

    class _Cfg:
        num_moe_layers = 2

    class _Placement:
        num_shadowed = 1

    def __init__(self, delay=0.02):
        self.cfg = self._Cfg()
        self.delay = delay
        self.placements_version = 0
        self.observed = []
        self.observe_thread = None

    def observe(self, per_layer_g, pool=None):
        self.observe_thread = threading.current_thread()
        time.sleep(self.delay)
        self.observed.append([g.copy() for g in per_layer_g])
        self.placements_version += 1

    def predicted_times(self):
        return {"predicted": 1.0, "baseline": 2.0, "speedup": 2.0}

    @property
    def placements(self):
        return [self._Placement(), self._Placement()]


class TestPlanPipelineOrdering:
    def test_wait_joins_before_dependent_dispatch(self):
        eng = _SlowEngine(delay=0.03)
        with PlanPipeline(eng) as pipe:
            for i in range(10):
                counts = np.full((2, 1, 4), i, dtype=np.int32)
                pipe.submit(counts)
                # ... the device step would run here ...
                event = pipe.wait()
                # The dependent dispatch happens after wait(): the engine
                # must already hold the result of observe(counts_i).
                assert eng.placements_version == i + 1
                assert event.version == i + 1
                assert len(eng.observed) == i + 1
                np.testing.assert_array_equal(
                    eng.observed[-1][0], np.full((1, 4), float(i)))
                assert event.plan_time >= eng.delay * 0.5
                assert 0.0 <= event.exposed <= event.plan_time + 1e-6
        # planning ran off the dispatch path
        assert eng.observe_thread is not threading.current_thread()

    def test_double_submit_asserts(self):
        eng = _SlowEngine(delay=0.0)
        with PlanPipeline(eng) as pipe:
            pipe.submit(np.zeros((2, 1, 4), np.int32))
            with pytest.raises(AssertionError):
                pipe.submit(np.zeros((2, 1, 4), np.int32))
            pipe.wait()

    def test_wait_without_submit_is_noop(self):
        eng = _SlowEngine()
        with PlanPipeline(eng) as pipe:
            assert pipe.wait() is None

    def test_observe_error_becomes_fallback_event(self):
        """The watchdog converts a planner explosion into a failed
        PlanEvent instead of propagating — training must continue on the
        last-good placements, and the next submit runs on a fresh
        worker."""
        eng = _SlowEngine()

        def boom(*a, **k):
            raise RuntimeError("planner exploded")
        eng.observe = boom
        with PlanPipeline(eng) as pipe:
            pipe.submit(np.zeros((2, 1, 4), np.int32))
            event = pipe.wait()
            assert event is not None and not event.ok
            assert event.failure == "planner_exception"
            assert pipe.worker_restarts == 1
            # the pipeline stays usable: a healthy plan lands afterwards
            del eng.observe            # un-shadow the class method
            eng.delay = 0.0
            pipe.submit(np.zeros((2, 1, 4), np.int32))
            event = pipe.wait()
            assert event.ok and eng.placements_version == 1


# ---------------------------------------------------------------------------
# Incremental engine packing + placement cache
# ---------------------------------------------------------------------------

class TestIncrementalStepArrays:
    def _skewed(self, d, e, hot, tokens=1000.0):
        g = np.ones((d, e), dtype=np.float64)
        g[:, hot] = tokens
        return g

    def test_version_bumps_only_on_change(self):
        eng = _engine(layers=2, d=4, e=8)
        v0 = eng.placements_version
        g = self._skewed(4, 8, hot=0)
        eng.observe([g, g])
        assert eng.placements_version > v0
        v1 = eng.placements_version
        eng.observe([g, g])                 # same distribution ⇒ same plan
        assert eng.placements_version == v1
        eng.observe([self._skewed(4, 8, hot=5)] * 2)
        assert eng.placements_version > v1

    def test_incremental_pack_matches_full_pack(self):
        eng = _engine(layers=3, d=4, e=8)
        rng = np.random.default_rng(0)
        for it in range(6):
            gs = [rng.integers(0, 200, size=(4, 8)).astype(np.float64)
                  for _ in range(3)]
            eng.observe(gs)
            got = eng.step_arrays()
            # oracle: pack every layer from scratch
            for li, pl in enumerate(eng.placements):
                ref = pl.to_device_arrays(eng.cfg.s_max)
                np.testing.assert_array_equal(got["shadow_idx"][li],
                                              ref["shadow_idx"])
                np.testing.assert_array_equal(got["shadow_valid"][li],
                                              ref["shadow_valid"])
                np.testing.assert_array_equal(got["shadow_devs"][li],
                                              ref["shadow_devs"])

    def test_step_arrays_returns_copies(self):
        eng = _engine(layers=1, d=4, e=8)
        eng.observe([self._skewed(4, 8, hot=0)])
        a = eng.step_arrays()
        a["shadow_idx"][:] = -7
        b = eng.step_arrays()
        assert not (b["shadow_idx"] == -7).any()

    def test_parallel_observe_matches_serial(self):
        from concurrent.futures import ThreadPoolExecutor
        rng = np.random.default_rng(3)
        gs_seq = [[rng.integers(0, 300, size=(4, 8)).astype(np.float64)
                   for _ in range(4)] for _ in range(5)]
        e_ser, e_par = _engine(layers=4), _engine(layers=4)
        with ThreadPoolExecutor(max_workers=3) as pool:
            for gs in gs_seq:
                e_ser.observe(gs)
                e_par.observe(gs, pool=pool)
                assert e_ser.placements == e_par.placements
                assert e_ser.placements_version == e_par.placements_version
        a, b = e_ser.step_arrays(), e_par.step_arrays()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])

    def test_placement_cache_uploads_only_on_change(self):
        eng = _engine(layers=2, d=4, e=8)
        cache = PlacementCache(eng)
        g = self._skewed(4, 8, hot=0)
        eng.observe([g, g])
        a1 = cache.arrays_for_dispatch()
        assert cache.uploads == 1 and cache.last_upload_time > 0.0
        eng.observe([g, g])                 # unchanged plan
        a2 = cache.arrays_for_dispatch()
        assert cache.uploads == 1 and cache.last_upload_time == 0.0
        assert a2 is a1                     # double buffer reused
        eng.observe([self._skewed(4, 8, hot=5)] * 2)
        a3 = cache.arrays_for_dispatch()
        assert cache.uploads == 2 and a3 is not a1

    def test_fingerprint_tracks_content(self):
        x = {"a": np.arange(4), "b": np.zeros((2, 2))}
        y = {"a": np.arange(4), "b": np.zeros((2, 2))}
        assert fingerprint_arrays(x) == fingerprint_arrays(y)
        y["b"][0, 0] = 1.0
        assert fingerprint_arrays(x) != fingerprint_arrays(y)
        assert fingerprint_arrays(None) == ""


# ---------------------------------------------------------------------------
# GreedyPlanner incremental Replace_Inputs == full recomputation
# ---------------------------------------------------------------------------

def _plan_oracle(planner, g):
    """The pre-refactor greedy search: full compute_loads per move."""
    g = np.asarray(g, dtype=np.float64)
    D, E = g.shape
    total_inputs = float(g.sum())
    eval_time = (planner.perf.layer_time_scheduled if planner.scheduled
                 else planner.perf.layer_time)
    placement = traditional(E, D)
    H, R = placement.compute_loads(g)
    t_best = eval_time(R, H, 0, planner.n)
    used, moves, cnt = set(), [], 0
    owner = placement.owner
    tokens_per_expert = g.sum(axis=0)
    cur = placement
    while (H.max() - H.min()) >= planner.alpha * total_inputs / E \
            and len(moves) < planner.s_max:
        heavy = int(np.argmax(H))
        if heavy in used:
            break
        used.add(heavy)
        resident = [e for e in np.where(owner == heavy)[0]
                    if e not in cur.shadows]
        if not resident:
            break
        e = int(resident[int(np.argmax(tokens_per_expert[resident]))])
        order = np.argsort(g[:, e], kind="stable")
        bottoms = [int(d) for d in order if int(d) != heavy][: planner.n]
        devs = frozenset(range(D)) - {heavy} - set(bottoms)
        cur = cur.with_shadow(e, devs)
        moves.append((e, devs))
        H, R = cur.compute_loads(g)
        t = eval_time(R, H, len(moves), planner.n)
        if t < t_best:
            t_best, cnt = t, len(moves)
    best = traditional(E, D)
    for e, devs in moves[:cnt]:
        best = best.with_shadow(e, devs)
    return best, t_best


class TestIncrementalGreedy:
    @pytest.mark.parametrize("n", [0, 2])
    @pytest.mark.parametrize("scheduled", [False, True])
    def test_matches_full_recompute_oracle(self, n, scheduled):
        d = 8
        perf = PerfModel(_hw(), d)
        planner = GreedyPlanner(perf, n=n, alpha=0.1, s_max=6,
                                scheduled=scheduled)
        for seed in range(15):
            g = GatingTrace(d, d * 2, 1024, skew=0.2, drift=0.0,
                            seed=seed).step()
            res = planner.plan(g)
            best, t_best = _plan_oracle(planner, g)
            assert dict(res.placement.shadows) == dict(best.shadows), seed
            assert res.predicted_time == pytest.approx(t_best, abs=0.0), seed


# ---------------------------------------------------------------------------
# Satellite: mesh axis-name selection
# ---------------------------------------------------------------------------

class TestMeshAxisNames:
    def test_explicit_ranks(self):
        assert mesh_axis_names(1) == ("model",)
        assert mesh_axis_names(2) == ("data", "model")
        assert mesh_axis_names(3) == ("pod", "data", "model")

    def test_rejects_bad_rank(self):
        with pytest.raises(ValueError):
            mesh_axis_names(4)
        with pytest.raises(ValueError):
            mesh_axis_names(0)

    def test_single_axis_mesh_builds(self):
        """`--mesh 1` analogue: a 1-axis mesh no longer crashes."""
        import jax as _jax
        mesh = _jax.make_mesh((1,), mesh_axis_names(1))
        assert mesh.axis_names == ("model",)


# ---------------------------------------------------------------------------
# StepStats / telemetry unit behaviour
# ---------------------------------------------------------------------------

class TestTelemetry:
    def test_hidden_frac(self):
        st = StepStats(step=0, loss=1.0, step_time=1.0, plan_time=0.1,
                       exposed_plan_time=0.025)
        assert st.hidden_frac == pytest.approx(0.75)
        assert StepStats(step=0, loss=1.0, step_time=1.0).hidden_frac == 0.0

    def test_overlap_summary(self):
        tel = OverlapTelemetry()
        tel.record(plan=0.1, step=1.0, exposed=0.0, upload=0.01)
        tel.record(plan=0.1, step=1.0, exposed=0.05, upload=0.0)
        s = tel.summary()
        assert s["hidden_frac"] == pytest.approx(0.75)
        assert s["host_overhead_s"] == pytest.approx((0.05 + 0.01) / 2)
        assert s["serial_overhead_s"] == pytest.approx((0.2 + 0.01) / 2)
        assert s["serial_overhead_s"] > s["host_overhead_s"]

    def test_log_line_uses_precomputed_fields(self):
        st = StepStats(step=3, loss=2.5, step_time=0.5, plan_time=0.02,
                       exposed_plan_time=0.0, plan_speedup=1.4,
                       num_shadowed=5)
        line = st.log_line(0.5)
        assert "loss 2.5000" in line and "plan_speedup=1.40x" in line
        assert "shadows=5" in line and "hidden=100%" in line
